"""FederationService / transport / error-taxonomy tests.

The conformance matrix (test_coordinator_conformance.py) already proves a
RemoteCoordinator behaves like a local coordinator on the happy paths; this
file locks down the serving layer itself: the canonical error taxonomy over
the wire (corrupt / oversized / queue-full must map to the right codes AND
leave coordinator state untouched), framed multi-report streaming with
backpressure, the personalized-solve endpoint's math, transport equivalence
(in-proc bytes == HTTP bytes), multi-federation routing, and the sharded
coordinator's occupancy/rebalance placement primitives.
"""

import asyncio

import numpy as np
import pytest

from repro.fl import (AFLServer, AsyncAFLServer, ClientReport,
                      FederationService, HttpTransport, InProcTransport,
                      RemoteCoordinator, ShardedCoordinator, make_report,
                      serve_http)
from repro.fl import errors as E
from repro.fl.service import frame_reports, pack_message, unpack_message

DIM, C, GAMMA = 16, 4, 1.0


def _reports(n=6, rows=5, seed=0, start_id=0):
    rng = np.random.default_rng(seed)
    return [make_report(start_id + k, rng.standard_normal((rows, DIM)),
                        np.eye(C)[rng.integers(0, C, rows)], GAMMA)
            for k in range(n)]


def _service(**kw):
    return FederationService(AFLServer(DIM, C, gamma=GAMMA), **kw)


# ---------------------------------------------------------------------------
# Error taxonomy over the wire
# ---------------------------------------------------------------------------


class TestErrorTaxonomy:
    def test_corrupt_payload_maps_to_corrupt_report_and_keeps_state(self):
        svc = _service()
        rc = RemoteCoordinator(svc)
        rc.submit(_reports(1)[0])
        wire = bytearray(_reports(1, start_id=50)[0].to_bytes())
        wire[len(wire) // 2] ^= 0xFF                       # bit flip
        with pytest.raises(E.CorruptReport) as exc:
            rc.submit_bytes(bytes(wire))
        assert exc.value.code == "corrupt_report"
        assert isinstance(exc.value, ValueError)           # taxonomy contract
        assert rc.num_clients == 1                         # state untouched
        assert svc.coordinator().num_clients == 1

    def test_oversized_report_rejected_before_parsing(self):
        svc = _service(max_report_bytes=256)
        rc = RemoteCoordinator(svc)
        payload = _reports(1)[0].to_bytes()                # ≫ 256 bytes
        with pytest.raises(E.OversizedReport) as exc:
            rc.submit_bytes(payload)
        assert exc.value.code == "oversized_report"
        assert rc.num_clients == 0

    def test_queue_full_maps_to_backpressure_and_keeps_state(self):
        svc = FederationService(AsyncAFLServer(DIM, C, gamma=GAMMA),
                                max_pending=0)
        try:
            rc = RemoteCoordinator(svc)
            with pytest.raises(E.Backpressure) as exc:
                rc.submit(_reports(1)[0])
            assert exc.value.code == "backpressure"
            assert exc.value.retryable                     # client may retry
            assert rc.num_clients == 0
        finally:
            svc.close()

    def test_async_server_enqueue_honors_its_own_watermark(self):
        """The coordinator-level backpressure hook (no service involved):
        with max_pending set, a full ingest queue refuses enqueue()."""
        reps = _reports(3)

        async def body():
            srv = AsyncAFLServer(DIM, C, gamma=GAMMA, max_pending=2)
            # no worker started → nothing drains: deterministic queue depth
            await srv.enqueue(reps[0])
            await srv.enqueue(reps[1])
            with pytest.raises(E.Backpressure):
                await srv.enqueue(reps[2])
            assert srv.pending == 2

        asyncio.run(body())

    def test_duplicate_and_gamma_mismatch_codes(self):
        rc = RemoteCoordinator(_service())
        reps = _reports(2)
        rc.submit(reps[0])
        # byte-identical resubmit: idempotent success (transport retries
        # must not see a spurious 409); CONFLICTING stats under the same
        # id is the real duplicate
        rc.submit(reps[0])
        with pytest.raises(E.DuplicateClient) as exc:
            rc.submit(_reports(1, seed=3)[0])
        assert exc.value.code == "duplicate_client"
        bad_gamma = make_report(99, np.zeros((3, DIM)), np.zeros((3, C)), 2.0)
        with pytest.raises(E.GammaMismatch) as exc:
            rc.submit(bad_gamma)
        assert exc.value.code == "gamma_mismatch"
        with pytest.raises(E.EmptyFederation):
            RemoteCoordinator(_service()).solve()

    def test_unknown_federation_and_route(self):
        svc = _service()
        with pytest.raises(E.UnknownFederation):
            RemoteCoordinator(svc, federation="nope")
        data, status = svc.handle("no_such_route", b"")
        header, _, _ = unpack_message(data)
        assert status == 400 and header["error"] == "bad_request"

    def test_internal_errors_never_leak_raw_exceptions(self):
        """A handler blowing up yields a structured 'internal' envelope, not
        a transport-level crash."""
        svc = _service()

        class Boom(RuntimeError):
            pass

        def explode(*a, **k):
            raise Boom("kaboom")

        svc.coordinator().solve = explode
        svc.coordinator().submit_many(_reports(2))
        data, status = svc.handle("solve", b"")
        header, _, _ = unpack_message(data)
        assert status == 500 and header["error"] == "internal"
        assert "kaboom" in header["message"]


# ---------------------------------------------------------------------------
# Streaming ingest
# ---------------------------------------------------------------------------


class TestSubmitStream:
    def test_mixed_batch_partial_acceptance(self):
        """One framed request carrying good + corrupt + duplicate reports:
        each frame succeeds/fails independently with its own code. The
        duplicate frame carries CONFLICTING stats for an already-folded
        client id — a byte-identical replay would be answered as idempotent
        success instead (TestIdempotentIngest)."""
        rc = RemoteCoordinator(_service())
        reps = _reports(3)
        conflict = _reports(1, seed=9)[0]       # same client id, new stats
        frames = [reps[0].to_bytes(), b"garbage", reps[1].to_bytes(),
                  conflict.to_bytes(), reps[2].to_bytes()]
        out = rc.submit_stream(frames)
        codes = [r.get("error") for r in out["results"]]
        assert out["accepted"] == 3
        assert codes == [None, "corrupt_report", None, "duplicate_client",
                         None]
        assert rc.num_clients == 3

    def test_stream_into_async_queue_and_drain(self):
        svc = FederationService(AsyncAFLServer(DIM, C, gamma=GAMMA))
        try:
            with serve_http(svc) as http:
                rc = RemoteCoordinator(http.url)
                reps = _reports(8)
                out = rc.submit_stream([r.to_bytes() for r in reps])
                assert out["accepted"] == 8
                assert all(r.get("queued") for r in out["results"])
                # fire-and-forget: the worker drains in arrival order
                for _ in range(200):
                    if rc.num_clients == 8 and rc.pending == 0:
                        break
                ref = AFLServer(DIM, C, gamma=GAMMA)
                ref.submit_many(reps)
                np.testing.assert_array_equal(rc.solve(), ref.solve())
        finally:
            svc.close()

    def test_malformed_framing_is_bad_request(self):
        svc = _service()
        data, status = svc.handle("submit_stream", b"\x05\x00\x00\x00tiny")
        header, _, _ = unpack_message(data)
        assert status == 400 and header["error"] == "bad_request"


# ---------------------------------------------------------------------------
# Personalization endpoint
# ---------------------------------------------------------------------------


class TestPersonalizedSolve:
    def test_gamma_only_matches_plain_solve(self):
        rc = RemoteCoordinator(_service())
        rc.submit_many(_reports())
        np.testing.assert_array_equal(rc.personalized_solve(0.7),
                                      rc.solve(0.7))

    def test_local_stats_mixture_math(self):
        """(C_agg + β·C_k + γ_t·I) W = Q_agg + β·Q_k — checked against a
        direct dense solve, through real wire bytes."""
        reps = _reports()
        rc = RemoteCoordinator(_service())
        rc.submit_many(reps)
        mine, beta, tg = reps[2], 3.0, 0.25
        w = rc.personalized_solve(tg, report=mine, mix_weight=beta)

        eye = np.eye(DIM)
        agg_g = sum(r.gram - GAMMA * eye for r in reps)
        agg_q = sum(r.moment for r in reps)
        raw_k = mine.gram - GAMMA * eye
        expected = np.linalg.solve(agg_g + beta * raw_k + tg * eye,
                                   agg_q + beta * mine.moment)
        np.testing.assert_allclose(w, expected, rtol=1e-8, atol=1e-10)
        # personalization reads the aggregate, never writes it
        assert rc.num_clients == len(reps)
        np.testing.assert_array_equal(rc.personalized_solve(tg), rc.solve(tg))

    def test_mixture_tilts_toward_the_clients_local_solution(self):
        """As β grows, the personalized head converges to the client's own
        local solve — the aggregate becomes a prior, not the answer. (The
        client needs ≥ d local rows so its raw Gram is full-rank and the
        β → ∞ limit is well-posed.)"""
        reps = _reports()
        mine = _reports(1, rows=4 * DIM, seed=9, start_id=42)[0]
        rc = RemoteCoordinator(_service())
        rc.submit_many(reps + [mine])
        raw_k = mine.gram - GAMMA * np.eye(DIM)
        w_local = np.linalg.solve(raw_k, mine.moment)
        devs = [np.abs(rc.personalized_solve(1.0, report=mine, mix_weight=b)
                       - w_local).max()
                for b in (0.0, 10.0, 1000.0)]
        assert devs[2] < devs[1] < devs[0]

    def test_empty_federation_rejected(self):
        rc = RemoteCoordinator(_service())
        with pytest.raises(E.EmptyFederation):
            rc.personalized_solve(0.0, report=_reports(1)[0], mix_weight=1.0)


# ---------------------------------------------------------------------------
# Transport equivalence + multi-federation routing
# ---------------------------------------------------------------------------


class TestTransports:
    def test_inproc_and_http_return_identical_bytes(self):
        svc = _service()
        svc.coordinator().submit_many(_reports())
        inproc = InProcTransport(svc)
        with serve_http(svc) as http:
            over_http = HttpTransport(http.url)
            for route, body in [("describe", b""),
                                ("solve", pack_message({"target_gamma": 0.5})),
                                ("state", b"")]:
                assert inproc.request(route, body) == \
                    over_http.request(route, body)

    def test_multiple_federations_are_isolated(self):
        svc = FederationService(AFLServer(DIM, C, gamma=GAMMA),
                                federation_id="team-a")
        svc.add_federation("team-b", AFLServer(DIM, C, gamma=GAMMA))
        a = RemoteCoordinator(svc, federation="team-a")
        b = RemoteCoordinator(svc, federation="team-b")
        a.submit_many(_reports(4, seed=1))
        b.submit_many(_reports(2, seed=2, start_id=100))
        assert (a.num_clients, b.num_clients) == (4, 2)
        assert svc.federation_ids() == ["team-a", "team-b"]
        assert np.abs(a.solve() - b.solve()).max() > 0

    def test_remote_results_are_writable_like_local_ones(self):
        """Zero call-site changes includes mutability: a caller that
        post-processes weights in place must not care that the arrays
        arrived over a wire (frombuffer views are read-only — copy)."""
        rc = RemoteCoordinator(_service())
        rc.submit_many(_reports(3))
        w = rc.solve()
        w *= 2.0
        vw = rc.weights()
        vw.weight[0, 0] += 1.0
        st = rc.state()
        st["gram"][0, 0] += 1.0

    def test_http_get_works_for_reads(self):
        import urllib.request

        svc = _service()
        svc.coordinator().submit_many(_reports(2))
        with serve_http(svc) as http:
            with urllib.request.urlopen(
                    f"{http.url}/v1/default/describe") as resp:
                header, _, _ = unpack_message(resp.read())
        assert header["ok"] and header["num_clients"] == 2

    def test_checkpoint_roundtrip_through_remote_state(self, tmp_path):
        """repro.checkpoint speaks the service: save a remote federation's
        state, restore it into a local server, resume submitting."""
        from repro import checkpoint as ckpt

        reps = _reports()
        rc = RemoteCoordinator(_service())
        rc.submit_many(reps[:4])
        ckpt.save_server(tmp_path / "fed", rc)
        back = ckpt.load_server(tmp_path / "fed")
        assert back.num_clients == 4
        back.submit_many(reps[4:])
        ref = AFLServer(DIM, C, gamma=GAMMA)
        ref.submit_many(reps)
        np.testing.assert_allclose(back.solve(), ref.solve(), rtol=1e-9,
                                   atol=1e-12)


# ---------------------------------------------------------------------------
# Sharded placement: occupancy + rebalance
# ---------------------------------------------------------------------------


def _sharded(n_shards=4, **kw):
    """A ShardedCoordinator widened to ``n_shards`` host accumulators.

    Placement (load-aware/round-robin, occupancy, rebalance) is pure
    host-side list manipulation — independent of the device mesh — so
    padding the shard list lets a 1-device CI host exercise multi-shard
    placement. (The device-mesh solve path is covered by the x64 subprocess
    test in test_coordinator_conformance.py.)
    """
    coord = ShardedCoordinator(DIM, C, gamma=GAMMA, **kw)
    while len(coord._shards) < n_shards:
        coord._shards.append(coord.engine.init(DIM, C))
    return coord


class TestShardedPlacementOps:
    def test_occupancy_tracks_round_robin_and_lands_in_state(self):
        coord = _sharded(4)
        reps = _reports(7)
        coord.submit_many(reps)
        occ = coord.occupancy()
        assert sum(occ) == 7 and max(occ) - min(occ) <= 1
        state = coord.state()
        np.testing.assert_array_equal(state["shard_clients"], occ)
        # extra key must not break cross-kind restore
        srv = AFLServer.from_state(state)
        assert srv.num_clients == 7

    def test_rebalance_moves_fullest_into_emptiest_invariantly(self):
        # round-robin placement so the cursor trick below can force a skew
        # (load-aware placement would route the pile-up away by itself)
        coord = _sharded(4, placement="round_robin")
        reps = _reports(9)
        # skew placement: everything lands in shard 0
        for r in reps:
            coord.submit(r)
            coord._order = 0
        assert coord.occupancy()[0] == 9
        before = coord.state()
        moved = coord.rebalance()
        assert moved is not None and moved[0] == 0
        occ = coord.occupancy()
        assert occ[0] == 0 and sum(occ) == 9
        after = coord.state()
        # statistics are additive ⇒ the aggregate is migration-invariant
        np.testing.assert_allclose(after["gram"], before["gram"],
                                   rtol=1e-12, atol=1e-9)
        np.testing.assert_allclose(after["moment"], before["moment"],
                                   rtol=1e-12, atol=1e-9)
        # no ping-pong: the blob just migrated is not migrated back — a
        # `while coord.rebalance(): ...` operator loop must terminate
        assert coord.rebalance() is None
        # a new submission opens the next epoch and re-arms rebalance
        coord.submit(_reports(1, start_id=77)[0])
        assert coord.rebalance() is not None

    def test_rebalance_noop_when_balanced(self):
        coord = _sharded(3)
        coord.submit_many(_reports(3))                 # one client per shard
        assert coord.rebalance() is None
        assert _sharded(1).rebalance() is None         # nothing to move to


class TestLoadAwarePlacement:
    """`submit` routes to the emptiest shard so rebalance() is rarely
    needed; ties fall back to the round-robin cursor."""

    def test_uniform_traffic_degenerates_to_round_robin(self):
        la, rr = _sharded(4), _sharded(4, placement="round_robin")
        reps = _reports(10)
        la.submit_many(reps)
        rr.submit_many(reps)
        assert la.occupancy() == rr.occupancy()

    def test_skewed_restore_fills_empty_shards_first(self):
        """After a restore (everything in shard 0), load-aware placement
        sends new arrivals to the empty shards — no rebalance() needed."""
        seed_coord = _sharded(4)
        seed_coord.submit_many(_reports(4))
        coord = ShardedCoordinator.from_state(seed_coord.state())
        while len(coord._shards) < 4:
            coord._shards.append(coord.engine.init(DIM, C))
        assert coord.occupancy() == [4, 0, 0, 0]
        coord.submit_many(_reports(3, seed=5, start_id=100))
        assert coord.occupancy() == [4, 1, 1, 1]
        assert coord.rebalance() is not None           # still available…
        # …but the placement itself kept the max-min gap from growing

    def test_aggregate_invariant_vs_round_robin(self):
        """Placement policy must never change the math: same reports, same
        aggregate, same solution (to f64 summation-order roundoff — which
        list slot holds a report differs, so the adds reassociate)."""
        la, rr = _sharded(4), _sharded(4, placement="round_robin")
        reps = _reports(9, seed=11)
        # interleave with a skew so the two policies actually diverge
        for i, r in enumerate(reps):
            la.submit(r)
            rr.submit(r)
            if i % 3 == 0:
                la._order = 0
                rr._order = 0
        assert la.occupancy() != rr.occupancy()        # policies did diverge
        sa, sr = la.state(), rr.state()
        np.testing.assert_allclose(sa["gram"], sr["gram"],
                                   rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(sa["moment"], sr["moment"],
                                   rtol=1e-12, atol=1e-12)
        np.testing.assert_array_equal(sa["seen"], sr["seen"])

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError):
            ShardedCoordinator(DIM, C, gamma=GAMMA, placement="hash")


class TestHttpKeepAlive:
    """`HttpTransport` reuses its connection (PR-4 ROADMAP rung): one TCP
    handshake per thread, not per request — with a transparent one-retry
    reconnect when a pooled socket has gone stale."""

    def test_connection_is_reused_and_answers_match_fresh(self):
        svc = _service()
        svc.coordinator().submit_many(_reports())
        with serve_http(svc) as http:
            reuse = HttpTransport(http.url)
            fresh = HttpTransport(http.url, keep_alive=False)
            try:
                first = reuse.request("describe")
                conn = reuse._local.conn
                assert conn is not None                # pooled…
                for _ in range(4):
                    assert reuse.request("describe") == first
                assert reuse._local.conn is conn       # …and actually reused
                assert len(reuse._pool) == 1
                # same bytes as the one-shot transport
                body = pack_message({"target_gamma": 0.25})
                assert reuse.request("solve", body) == \
                    fresh.request("solve", body)
            finally:
                reuse.close()
                fresh.close()
            assert not reuse._pool

    def test_dead_thread_connections_are_swept(self):
        """Thread churn must not leak sockets: a connection pooled by a
        thread that has exited is closed on the next pool access."""
        import threading

        svc = _service()
        with serve_http(svc) as http:
            t = HttpTransport(http.url)
            try:
                worker = threading.Thread(
                    target=lambda: t.request("describe"))
                worker.start()
                worker.join()
                assert len(t._pool) == 1           # dead thread's conn…
                t.request("describe")              # …swept on next access
                assert list(t._pool) == [threading.current_thread()]
            finally:
                t.close()

    def test_stale_pooled_socket_reconnects_transparently(self):
        svc = _service()
        with serve_http(svc) as http:
            t = HttpTransport(http.url)
            try:
                t.request("describe")
                # simulate a server-side idle close of the kept-alive socket
                t._local.conn.sock.close()
                header, _, _ = unpack_message(t.request("describe"))
                assert header["ok"]                    # retried on a fresh conn
            finally:
                t.close()

    def test_reuse_vs_fresh_timing_smoke(self):
        """Assert-free timing smoke: exercise both modes back-to-back so a
        perf regression shows up in logs without flaking CI."""
        import time

        svc = _service()
        svc.coordinator().submit_many(_reports(2))
        with serve_http(svc) as http:
            for label, transport in [
                    ("keep-alive", HttpTransport(http.url)),
                    ("fresh-conn", HttpTransport(http.url,
                                                 keep_alive=False))]:
                t0 = time.perf_counter()
                for _ in range(20):
                    transport.request("describe")
                dt = time.perf_counter() - t0
                transport.close()
                print(f"{label}: 20 describes in {1e3 * dt:.1f}ms")


class TestIdempotentIngest:
    """Transport retries must never double-apply or surface a spurious 409:
    the service keys accepted submissions on (client id, payload CRC) and
    answers a re-delivered identical payload with success."""

    def test_identical_payload_retry_answers_success_once_applied(self):
        svc = _service()
        payload = _reports(1)[0].to_bytes()
        header, _ = svc.handle("submit", payload)
        first, _, _ = unpack_message(header)
        assert first["ok"] and first["duplicate"] is False
        again, _ = svc.handle("submit", payload)
        h, _, _ = unpack_message(again)
        assert h["ok"] and h["duplicate"] is True
        assert h["num_clients"] == 1
        assert svc.coordinator().num_clients == 1      # applied exactly once

    def test_different_payload_same_client_still_conflicts(self):
        svc = _service()
        rc = RemoteCoordinator(svc)
        rc.submit(_reports(1)[0])
        with pytest.raises(E.DuplicateClient):
            rc.submit(_reports(1, seed=9)[0])          # same id, new stats

    def test_submit_stream_frames_are_idempotent(self):
        svc = _service()
        rc = RemoteCoordinator(svc)
        payload = _reports(1)[0].to_bytes()
        out = rc.submit_stream([payload, payload])
        assert out["accepted"] == 2
        assert out["results"][1]["duplicate"] is True
        assert svc.coordinator().num_clients == 1
        # a whole-batch replay (lost stream response) is also a no-op
        out = rc.submit_stream([payload])
        assert out["accepted"] == 1
        assert svc.coordinator().num_clients == 1

    def test_http_submit_replay_after_lost_response_is_transparent(self):
        """The send-phase retry bug: the first attempt lands but its
        response is lost on the kept-alive socket. The transport replays on
        a fresh connection; the service's idempotent ingest answers success
        — the client sees ONE successful submit, aggregated once."""
        import http.client

        svc = _service()
        with serve_http(svc) as http_srv:
            t = HttpTransport(http_srv.url)
            try:
                t.request("describe")                  # pool a connection
                conn = t._local.conn

                class _Lost(Exception):
                    pass

                real = conn.getresponse

                def lose_response():
                    real().read()                      # server DID apply it
                    raise http.client.HTTPException("response lost")

                conn.getresponse = lose_response
                rc = RemoteCoordinator(t)
                assert rc.submit(_reports(1)[0]) is not None
                assert svc.coordinator().num_clients == 1
            finally:
                t.close()
