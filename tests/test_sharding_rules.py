"""Sharding-rule unit tests against the production mesh *abstractly* (no
devices needed: AbstractMesh provides axis names/sizes for spec resolution)."""

import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.config import INPUT_SHAPES
from repro.configs.registry import get_config
from repro.launch import sharding as SH
from repro.models import transformer as T

# AbstractMesh takes a single shape tuple of (axis_name, size) pairs.
MESH_1POD = AbstractMesh((("data", 16), ("model", 16)))
MESH_2POD = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _specs(arch, mesh):
    cfg = get_config(arch)
    p_shape = jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg))
    return cfg, p_shape, SH.param_specs(p_shape, mesh)


def test_dense_param_rules_single_pod():
    cfg, p_shape, specs = _specs("qwen3_32b", MESH_1POD)
    lyr = specs["layers"]
    # stacked (L, d, features) col-parallel: leading stack dim replicated
    assert lyr["attn"]["wq"] == P(None, ("data",), ("model",))
    assert lyr["attn"]["wo"] == P(None, ("model",), ("data",))
    assert lyr["mlp"]["w_down"] == P(None, ("model",), ("data",))
    assert specs["embed"] == P(("model",), ("data",))
    # norms replicated
    assert lyr["ln1"]["scale"] == P()


def test_multi_pod_fsdp_axes():
    _, _, specs = _specs("qwen3_32b", MESH_2POD)
    assert specs["layers"]["attn"]["wq"] == P(None, ("pod", "data"), ("model",))


def test_divisibility_guard_drops_axis():
    # granite router: (d_model, E=40); 40 % 16 != 0 → E replicated
    _, _, specs = _specs("granite_moe_3b_a800m", MESH_1POD)
    assert specs["layers"]["moe"]["router"] == P(None, ("data",), None)
    # moe expert weights: (E, d_in, d_out) → E replicated, matrices sharded
    assert specs["layers"]["moe"]["w_up"] == P(None, None, ("data",), ("model",))


def test_minicpm_odd_heads_still_shards_flat_features():
    # 36 heads ∤ 16, but h*hd = 2304 is divisible → flat feature dim shards
    _, _, specs = _specs("minicpm_2b", MESH_1POD)
    assert specs["layers"]["attn"]["wq"] == P(None, ("data",), ("model",))


def test_cache_specs_decode_batched():
    cfg = get_config("qwen3_32b")
    shape = INPUT_SHAPES["decode_32k"]
    cache = jax.eval_shape(lambda: T.init_cache(cfg, shape.global_batch, 2048))
    specs = SH.cache_specs(cfg, cache, shape, MESH_1POD)
    # (L, B, Hk, S, hd): batch over data, head_dim over model, seq UNsharded
    # (a sharded update dim makes GSPMD sweep the cache — §Perf decode iter 2)
    assert specs["k"] == P(None, ("data",), None, None, ("model",))


def test_cache_specs_long_context_b1():
    cfg = get_config("zamba2_7b")
    shape = INPUT_SHAPES["long_500k"]
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))
    specs = SH.cache_specs(cfg, cache, shape, MESH_1POD)
    # B=1: the attention cache spreads its sequence over the idle data axis
    k = specs["attn"]["k"]
    norm = lambda e: e if isinstance(e, tuple) else (e,)
    assert norm(k[3]) == ("data",) and norm(k[-1]) == ("model",)


def test_batch_specs_shard_leading_dim():
    cfg = get_config("llava_next_mistral_7b")
    shape = INPUT_SHAPES["train_4k"]
    from repro.launch.inputs import input_specs
    sp = SH.batch_specs(cfg, input_specs(cfg, shape), MESH_2POD)
    assert sp["tokens"][0] == ("pod", "data")
    assert sp["prefix_embeds"][0] == ("pod", "data")
