"""Parity suite for the Pallas solve kernels + the paths that consume them.

The acceptance bar for the fused solve path: the blocked Cholesky, the
batched triangular solve, and the fused multi-γ sweep agree with the
``numpy_f64`` oracle — at f32 tolerances in-process (interpret-mode Pallas on
CPU, so tier-1 exercises the kernels without a TPU) and at **1e-10 under
``jax_enable_x64``** in a subprocess (x64 is process-global), including the
rank-deficient γ=0 ablation (kernel NaNs → eigendecomposition/pinv fallback)
and masked-cohort statistics. Also here: the rank-updated eigendecomposition
sweep handle (Woodbury ≡ fresh eigh; AFLServer cache lifecycle) and the
tiled-Gram ShardedCoordinator (row tiles ≡ whole-leaf sharding ≡ sync).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.engine import (AnalyticEngine, SweepRefreshNeeded)
from repro.fl import AFLServer, ShardedCoordinator, make_report, masked_reports
from repro.kernels import ops


def _spd(d, n_mult=4, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    mats = []
    for i in range(batch or 1):
        x = rng.standard_normal((n_mult * d, d))
        mats.append(x.T @ x + (0.5 + i) * np.eye(d))
    return np.stack(mats) if batch else mats[0]


class TestKernelParityF32:
    """Interpret-mode kernels vs numpy at f32 tolerances (CPU tier-1)."""

    @pytest.mark.parametrize("d,batch", [(32, 1), (48, 3), (130, 2)])
    def test_blocked_cholesky(self, d, batch):
        a = _spd(d, batch=batch)
        l = np.asarray(ops.blocked_cholesky(jnp.asarray(a, jnp.float32)))
        ref = np.stack([np.linalg.cholesky(a[i]) for i in range(batch)])
        np.testing.assert_allclose(l, ref, rtol=5e-5,
                                   atol=5e-5 * np.abs(ref).max())
        # clean lower factors: the strict upper triangle is exactly zero
        assert np.array_equal(np.triu(l, 1), np.zeros_like(l))

    @pytest.mark.parametrize("d,c,batch", [(48, 7, 3), (96, 5, 1)])
    def test_cholesky_solve(self, d, c, batch):
        rng = np.random.default_rng(1)
        a = _spd(d, batch=batch, seed=2)
        b = rng.standard_normal((batch, d, c))
        l = ops.blocked_cholesky(jnp.asarray(a, jnp.float32))
        x = np.asarray(ops.cholesky_solve(l, jnp.asarray(b, jnp.float32)))
        ref = np.stack([np.linalg.solve(a[i], b[i]) for i in range(batch)])
        np.testing.assert_allclose(x, ref, rtol=2e-4,
                                   atol=2e-4 * np.abs(ref).max())

    @pytest.mark.parametrize("n_gammas", [1, 3, 11])
    def test_multi_gamma_solve(self, n_gammas):
        d, c = 64, 6
        rng = np.random.default_rng(3)
        a = _spd(d, seed=3)
        q = rng.standard_normal((d, c))
        gammas = np.logspace(-2, 1, n_gammas)
        w = np.asarray(ops.multi_gamma_solve(
            jnp.asarray(a, jnp.float32), jnp.asarray(q, jnp.float32),
            jnp.asarray(gammas, jnp.float32)))
        assert w.shape == (n_gammas, d, c)
        for i, g in enumerate(gammas):
            ref = np.linalg.solve(a + g * np.eye(d), q)
            np.testing.assert_allclose(w[i], ref, rtol=2e-3,
                                       atol=2e-4 * np.abs(ref).max())

    @pytest.mark.parametrize("d,k", [(32, 2), (48, 5), (130, 3)])
    def test_chol_rank_update(self, d, k):
        """Fused rank-k factor update vs a fresh Cholesky of A + xsᵀxs,
        covering padded d (130) and k below/above the minimal pad."""
        rng = np.random.default_rng(7)
        a = _spd(d, seed=6)
        l = np.linalg.cholesky(a)
        xs = rng.standard_normal((k, d))
        out = np.asarray(ops.chol_rank_update(
            jnp.asarray(l, jnp.float32), jnp.asarray(xs, jnp.float32)))
        ref = np.linalg.cholesky(a + xs.T @ xs)
        np.testing.assert_allclose(out, ref, rtol=1e-3,
                                   atol=5e-4 * np.abs(ref).max())
        # clean lower factor: strict upper triangle exactly zero
        assert np.array_equal(np.triu(out, 1), np.zeros_like(out))

    def test_chol_rank_zero_is_identity(self):
        l = np.linalg.cholesky(_spd(24, seed=8))
        out = np.asarray(ops.chol_rank_update(
            jnp.asarray(l, jnp.float32), jnp.zeros((0, 24), jnp.float32)))
        assert np.array_equal(out, l.astype(np.float32))

    def test_singular_system_yields_nans_not_garbage(self):
        """γ=0 on a rank-deficient Gram must be *loud* (NaNs trip the
        engine's eigendecomposition fallback), never silently wrong."""
        rng = np.random.default_rng(4)
        d = 32
        x = rng.standard_normal((5, d))                # rank 5 < d
        w = np.asarray(ops.multi_gamma_solve(
            jnp.asarray(x.T @ x, jnp.float32),
            jnp.asarray(rng.standard_normal((d, 3)), jnp.float32),
            jnp.asarray([0.0, 1.0], jnp.float32)))
        assert not np.isfinite(w[0]).all()             # singular γ
        assert np.isfinite(w[1]).all()                 # PD γ unaffected

    def test_f32_x2_precision_variant_stays_within_f32(self):
        """The emulated-f64 product split guards MXUs that run f32 matmuls
        as bf16 passes; on exact-f32 hardware (CPU interpret) it must be
        ~neutral — same answer, no worse than plain f32."""
        d, c = 96, 5
        rng = np.random.default_rng(5)
        a = _spd(d, n_mult=8, seed=5)
        q = rng.standard_normal((d, c))
        ref = np.linalg.solve(a + 0.5 * np.eye(d), q)
        errs = {}
        for prec in ("native", "f32_x2"):
            w = np.asarray(ops.multi_gamma_solve(
                jnp.asarray(a, jnp.float32), jnp.asarray(q, jnp.float32),
                jnp.asarray([0.5], jnp.float32), precision=prec))
            errs[prec] = np.abs(w[0] - ref).max() / np.abs(ref).max()
        assert errs["f32_x2"] <= 4 * errs["native"] + 1e-9
        assert errs["f32_x2"] < 1e-4


class TestEngineKernelPath:
    """AnalyticEngine('jax', use_kernel=True): solve / factor_solve /
    solve_multi_gamma all route through the new kernels."""

    @staticmethod
    def _engines():
        return (AnalyticEngine("jax", gamma=1.0, use_kernel=True),
                AnalyticEngine("numpy_f64", gamma=1.0))

    def test_solve_and_factor_solve_match_oracle(self):
        ek, eh = self._engines()
        rng = np.random.default_rng(6)
        d, c = 40, 5
        x = rng.standard_normal((300, d))
        y = np.eye(c)[rng.integers(0, c, 300)]
        sk = ek.client_stats(jnp.asarray(x, jnp.float32),
                             jnp.asarray(y, jnp.float32))
        sh = eh.client_stats(x, y)
        w_ref = eh.solve(sh, target_gamma=0.1)
        np.testing.assert_allclose(
            np.asarray(ek.solve(sk, target_gamma=0.1)), w_ref, atol=3e-3)
        f = ek.factor(sk, target_gamma=0.1)
        np.testing.assert_allclose(
            np.asarray(ek.factor_solve(f, sk.moment)), w_ref, atol=3e-3)

    def test_factor_update_composes_with_kernel_factor(self):
        """rank_update on a kernel-produced handle keeps tracking the
        refactor (the async-serving seam with use_kernel on)."""
        ek, eh = self._engines()
        rng = np.random.default_rng(7)
        d, c = 32, 4
        x = rng.standard_normal((200, d))
        y = np.eye(c)[rng.integers(0, c, 200)]
        sk = ek.client_stats(jnp.asarray(x, jnp.float32),
                             jnp.asarray(y, jnp.float32))
        f = ek.factor(sk, target_gamma=0.1)
        xk = rng.standard_normal((4, d)).astype(np.float32)
        yk = np.eye(c)[rng.integers(0, c, 4)].astype(np.float32)
        s1 = ek.merge(sk, ek.client_stats(jnp.asarray(xk), jnp.asarray(yk)))
        f1 = ek.factor_update(f, s1, xk, target_gamma=0.1, max_rank=8)
        f_ref = ek.factor(s1, target_gamma=0.1)
        np.testing.assert_allclose(
            np.asarray(ek.factor_solve(f1, s1.moment)),
            np.asarray(ek.factor_solve(f_ref, s1.moment)), atol=3e-3)

    def test_multi_gamma_fused_matches_oracle(self):
        ek, eh = self._engines()
        rng = np.random.default_rng(8)
        d, c = 48, 5
        x = rng.standard_normal((400, d))
        y = np.eye(c)[rng.integers(0, c, 400)]
        sk = ek.client_stats(jnp.asarray(x, jnp.float32),
                             jnp.asarray(y, jnp.float32))
        sh = eh.client_stats(x, y)
        gammas = [0.01, 0.1, 1.0, 10.0]
        ws = ek.solve_multi_gamma(sk, gammas)
        ws_ref = eh.solve_multi_gamma(sh, gammas)
        for w, w_ref in zip(ws, ws_ref):
            np.testing.assert_allclose(np.asarray(w, np.float64), w_ref,
                                       rtol=2e-2,
                                       atol=2e-3 * np.abs(w_ref).max())

    def test_rank_deficient_gamma_zero_falls_back_to_eigh_path(self):
        """A singular γ in the grid reroutes the WHOLE sweep to the
        eigendecomposition path — the kernel engine must answer exactly
        what the non-kernel jax backend answers (the f64/pinv parity claim
        lives in the x64 subprocess, where the spectrum is clean)."""
        ek, _ = self._engines()
        ej = AnalyticEngine("jax", gamma=1.0)
        rng = np.random.default_rng(9)
        d, c = 24, 3
        x = rng.standard_normal((6, d))                # N < d: singular γ=0
        y = np.eye(c)[rng.integers(0, c, 6)]
        sj = ej.client_stats(jnp.asarray(x, jnp.float32),
                             jnp.asarray(y, jnp.float32))
        # identical stats into both engines: the fallback then runs the
        # same eigendecomposition on the same matrix
        ws = ek.solve_multi_gamma(sj, [0.0, 1.0])
        ws_ref = ej.solve_multi_gamma(sj, [0.0, 1.0])
        assert all(np.isfinite(np.asarray(w)).all() for w in ws)
        for w, w_ref in zip(ws, ws_ref):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(w_ref))


class TestSweepHandle:
    """The rank-updated eigendecomposition handle behind repeated sweeps."""

    def test_woodbury_updates_equal_fresh_eigh(self):
        eng = AnalyticEngine("numpy_f64", gamma=1.0)
        rng = np.random.default_rng(10)
        d, c = 36, 4
        stats = eng.client_stats(rng.standard_normal((250, d)),
                                 np.eye(c)[rng.integers(0, c, 250)])
        handle = eng.sweep_factor(stats)
        gammas = [0.01, 0.1, 1.0]
        for _ in range(4):
            xk = rng.standard_normal((3, d))
            yk = np.eye(c)[rng.integers(0, c, 3)]
            stats = eng.merge(stats, eng.client_stats(xk, yk))
            handle = handle.rank_update(xk)
        ws = eng.sweep_solve(handle, stats.moment, gammas)
        ws_ref = eng.solve_multi_gamma(stats, gammas)
        for w, w_ref in zip(ws, ws_ref):
            np.testing.assert_allclose(w, w_ref, rtol=1e-9, atol=1e-11)

    def test_rank_zero_is_bit_identical_to_direct_sweep(self):
        eng = AnalyticEngine("numpy_f64", gamma=1.0)
        rng = np.random.default_rng(11)
        d, c = 20, 3
        stats = eng.client_stats(rng.standard_normal((100, d)),
                                 np.eye(c)[rng.integers(0, c, 100)])
        handle = eng.sweep_factor(stats)
        for w, w_ref in zip(
                eng.sweep_solve(handle, stats.moment, [0.0, 0.5]),
                eng.solve_multi_gamma(stats, [0.0, 0.5])):
            np.testing.assert_array_equal(w, w_ref)

    def test_truncated_spectrum_with_updates_demands_refresh(self):
        """pinv truncation + pending updates cannot be answered exactly by
        Woodbury — the handle must refuse rather than drift."""
        eng = AnalyticEngine("numpy_f64", gamma=1.0)
        rng = np.random.default_rng(12)
        d, c = 16, 3
        x = rng.standard_normal((5, d))                # rank-deficient base
        stats = eng.client_stats(x, np.eye(c)[rng.integers(0, c, 5)])
        handle = eng.sweep_factor(stats).rank_update(
            rng.standard_normal((2, d)))
        with pytest.raises(SweepRefreshNeeded):
            eng.sweep_solve(handle, stats.moment, [0.0])

    def test_server_cache_lifecycle_and_results(self):
        rng = np.random.default_rng(13)
        DIM, C = 16, 4
        reps = [make_report(k, rng.standard_normal((5, DIM)),
                            np.eye(C)[rng.integers(0, C, 5)], 1.0)
                for k in range(8)]
        srv = AFLServer(DIM, C, gamma=1.0, sweep_rank_budget=64)
        srv.submit_many(reps[:5])
        gammas = [0.0, 0.1, 1.0]
        srv.solve_multi_gamma(gammas)
        assert srv._sweep_cache is not None and srv._sweep_cache.rank == 0
        srv.submit(reps[5])                            # low-rank root arrival
        assert srv._sweep_cache is not None and srv._sweep_cache.rank == 5
        ws = srv.solve_multi_gamma(gammas)
        fresh = AFLServer(DIM, C, gamma=1.0)
        fresh.submit_many(reps[:6])
        for w, w_ref in zip(ws, fresh.solve_multi_gamma(gammas)):
            np.testing.assert_allclose(w, w_ref, rtol=1e-9, atol=1e-11)
        # a rootless (masked) arrival kills the handle…
        srv.submit(masked_reports(reps[6:8], seed=3)[0])
        assert srv._sweep_cache is None
        # …and the rank budget caps accumulation
        tight = AFLServer(DIM, C, gamma=1.0, sweep_rank_budget=4)
        tight.submit_many(reps[:4])
        tight.solve_multi_gamma(gammas)
        tight.submit(reps[4])                          # 5 rows > budget 4
        assert tight._sweep_cache is None

    def test_masked_cohort_sweep_still_matches(self):
        """Masked uploads (no roots) force fresh handles every time — the
        sweep answers must still match the unmasked federation."""
        rng = np.random.default_rng(14)
        DIM, C = 12, 3
        reps = [make_report(k, rng.standard_normal((20, DIM)),
                            np.eye(C)[rng.integers(0, C, 20)], 1.0)
                for k in range(4)]
        plain, masked = AFLServer(DIM, C, 1.0), AFLServer(DIM, C, 1.0)
        plain.submit_many(reps)
        masked.submit_many(masked_reports(reps, seed=5))
        for w, w_ref in zip(masked.solve_multi_gamma([0.0, 1.0]),
                            plain.solve_multi_gamma([0.0, 1.0])):
            np.testing.assert_allclose(w, w_ref, rtol=1e-6, atol=1e-7)


class TestTiledGramCoordinator:
    """Host-side tiled-Gram semantics (the 8-way device path runs in the
    x64 subprocess below)."""

    def _reports(self, n=6, dim=16, c=4, seed=0):
        rng = np.random.default_rng(seed)
        return [make_report(k, rng.standard_normal((5, dim)),
                            np.eye(c)[rng.integers(0, c, 5)], 1.0)
                for k in range(n)]

    def test_tiles_assemble_to_the_sync_aggregate(self):
        reps = self._reports()
        tiled = ShardedCoordinator(16, 4, gamma=1.0, tiled_gram=True)
        sync = AFLServer(16, 4, gamma=1.0)
        tiled.submit_many(reps)
        sync.submit_many(reps)
        st, ss = tiled.state(), sync.state()
        np.testing.assert_allclose(st["gram"], ss["gram"],
                                   rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(st["moment"], ss["moment"],
                                   rtol=1e-12, atol=1e-12)
        assert float(st["count"]) == float(ss["count"])
        np.testing.assert_allclose(tiled.solve(0.5), sync.solve(0.5),
                                   rtol=1e-3, atol=2e-3)
        for w, w_ref in zip(tiled.solve_multi_gamma([0.1, 1.0]),
                            sync.solve_multi_gamma([0.1, 1.0])):
            np.testing.assert_allclose(w, w_ref, rtol=1e-9, atol=1e-12)

    def test_state_roundtrip_and_cross_kind(self):
        reps = self._reports(seed=1)
        tiled = ShardedCoordinator(16, 4, gamma=1.0, tiled_gram=True)
        tiled.submit_many(reps[:4])
        state = tiled.state()
        back = ShardedCoordinator.from_state(state, tiled_gram=True)
        assert back.num_clients == 4
        back.submit_many(reps[4:])
        ref = AFLServer.from_state(state)
        ref.submit_many(reps[4:])
        np.testing.assert_allclose(back.solve(0.2), ref.solve(0.2),
                                   rtol=1e-3, atol=2e-3)

    def test_rebalance_is_noop_and_occupancy_reports_rows(self):
        tiled = ShardedCoordinator(16, 4, gamma=1.0, tiled_gram=True)
        tiled.submit_many(self._reports(3, seed=2))
        assert tiled.rebalance() is None
        assert tiled.occupancy() == [16]               # 1 shard → whole d

    def test_indivisible_dim_pads_to_tile_multiple(self):
        """dim % shards != 0 pads up to the next tile multiple (zero pad
        rows, masked out of the solve); the loud construction error remains
        only when padding would cost a full extra tile. A duck-typed mesh
        stands in for the device mesh — the program is only built at solve."""

        class FakeMesh:
            axis_names = ("data",)
            shape = {"data": 4}

        coord = ShardedCoordinator(18, 4, gamma=1.0, tiled_gram=True,
                                   mesh=FakeMesh())
        assert coord.num_shards == 4
        assert coord._tile_rows == 5                   # ceil(18 / 4)
        assert coord._gram_tiles[0].shape == (5, 20)   # padded width
        # pad ≥ one whole tile is still rejected (dim=10 on 8 shards)
        class FakeMesh8:
            axis_names = ("data",)
            shape = {"data": 8}

        with pytest.raises(ValueError):
            ShardedCoordinator(10, 4, gamma=1.0, tiled_gram=True,
                               mesh=FakeMesh8())
        coord = ShardedCoordinator(16, 4, gamma=1.0, tiled_gram=True,
                                   mesh=FakeMesh())
        assert coord.num_shards == 4
        assert coord.occupancy() == [4, 4, 4, 4]       # 16 rows over 4 tiles


# ---------------------------------------------------------------------------
# x64 subprocess: the 1e-10 bit-parity bar + the d%8==0 tiled device solve
# ---------------------------------------------------------------------------

_X64_KERNEL_PARITY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_ENABLE_X64"] = "1"
    import numpy as np
    import jax.numpy as jnp
    from scipy.linalg import solve_triangular
    from repro.core.engine import AnalyticEngine
    from repro.fl import AFLServer, ShardedCoordinator, make_report, \\
        masked_reports
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    TOL = 1e-10

    def rel(a, b):
        return np.abs(np.asarray(a, np.float64) - b).max() / \\
            max(np.abs(b).max(), 1.0)

    # 1) blocked Cholesky vs numpy_f64, batched, padded shapes
    for d, batch in [(64, 2), (150, 3)]:
        mats = []
        for i in range(batch):
            x = rng.standard_normal((4 * d, d))
            mats.append(x.T @ x + (0.5 + i) * np.eye(d))
        a = np.stack(mats)
        l = ops.blocked_cholesky(jnp.asarray(a))
        ref = np.stack([np.linalg.cholesky(a[i]) for i in range(batch)])
        assert rel(l, ref) < TOL, ("cholesky", d, rel(l, ref))
        # 2) batched triangular solve vs scipy
        b = rng.standard_normal((batch, d, 7))
        xk = ops.cholesky_solve(l, jnp.asarray(b))
        refx = np.stack([
            solve_triangular(ref[i], solve_triangular(
                ref[i], b[i], lower=True), lower=True, trans="T")
            for i in range(batch)])
        assert rel(xk, refx) < TOL, ("cho_solve", d, rel(xk, refx))

    # 3) fused multi-gamma sweep vs the numpy_f64 oracle engine,
    #    including rank-deficient gamma=0 (kernel NaN -> eigh fallback)
    #    and masked-cohort statistics
    from repro.core.engine import SuffStats
    eng_k = AnalyticEngine("jax", gamma=1.0, use_kernel=True,
                           dtype=jnp.float64)
    eng_h = AnalyticEngine("numpy_f64", gamma=1.0)
    d, c = 72, 5
    gammas = [0.0, 0.01, 0.1, 1.0, 10.0]

    def to_dev(stats):
        # identical f64 statistics into both engines: the 1e-10 bar is on
        # the SOLVE kernels, not the (f32-accumulating) gram kernel
        return SuffStats(jnp.asarray(stats.gram), jnp.asarray(stats.moment),
                         jnp.asarray(stats.count),
                         jnp.asarray(stats.clients))

    x = rng.standard_normal((6 * d, d))
    y = np.eye(c)[rng.integers(0, c, 6 * d)]
    sh = eng_h.client_stats(x, y)
    sk = to_dev(sh)
    for w, w_ref in zip(eng_k.solve_multi_gamma(sk, gammas),
                        eng_h.solve_multi_gamma(sh, gammas)):
        assert rel(w, w_ref) < TOL, ("sweep", rel(w, w_ref))
    # direct solve + cached-factor path
    assert rel(eng_k.solve(sk, target_gamma=0.5),
               eng_h.solve(sh, target_gamma=0.5)) < TOL
    f = eng_k.factor(sk, target_gamma=0.5)
    assert rel(eng_k.factor_solve(f, sk.moment),
               eng_h.solve(sh, target_gamma=0.5)) < TOL

    # rank-deficient gamma=0: N < d
    xs = rng.standard_normal((10, d))
    ys = np.eye(c)[rng.integers(0, c, 10)]
    sh0 = eng_h.client_stats(xs, ys)
    sk0 = to_dev(sh0)
    for w, w_ref in zip(eng_k.solve_multi_gamma(sk0, gammas),
                        eng_h.solve_multi_gamma(sh0, gammas)):
        assert np.isfinite(np.asarray(w)).all()
        assert rel(w, w_ref) < TOL, ("rankdef", rel(w, w_ref))

    # masked-cohort statistics through an AFLServer (the serving sweep)
    DIM, C = 24, 4
    reps = [make_report(k, rng.standard_normal((8, DIM)),
                        np.eye(C)[rng.integers(0, C, 8)], 1.0)
            for k in range(6)]
    plain, masked = AFLServer(DIM, C, 1.0), AFLServer(DIM, C, 1.0)
    plain.submit_many(reps)
    masked.submit_many(masked_reports(reps, seed=9))
    for w, w_ref in zip(masked.solve_multi_gamma([0.0, 1.0]),
                        plain.solve_multi_gamma([0.0, 1.0])):
        assert rel(w, w_ref) < 1e-8

    # 4) tiled-Gram ShardedCoordinator on the 8-way mesh vs the sync path
    d8, c8 = 64, 5           # d % 8 == 0
    reps8 = [make_report(k, rng.standard_normal((16, d8)),
                         np.eye(c8)[rng.integers(0, c8, 16)], 1.0)
             for k in range(24)]
    tiled = ShardedCoordinator(d8, c8, gamma=1.0, tiled_gram=True)
    assert tiled.num_shards == 8
    assert all(t.shape == (8, d8) for t in tiled._gram_tiles)
    sync = AFLServer(d8, c8, gamma=1.0)
    for r in reps8:
        tiled.submit(r)
        sync.submit(r)
    for tg in (0.0, 0.5):
        err = np.abs(tiled.solve(tg) - sync.solve(tg)).max()
        assert err < 1e-6, ("tiled-vs-sync", tg, err)
    # whole-leaf sharded path agrees too (tile psum == leaf psum)
    leaf = ShardedCoordinator(d8, c8, gamma=1.0)
    leaf.submit_many(reps8)
    assert np.abs(tiled.solve(0.0) - leaf.solve(0.0)).max() < 1e-6

    # 5) fused rank-k Cholesky update vs the host Householder sweep,
    #    covering padding edges (d % block != 0) and k past a lane multiple
    from repro.core.engine import _chol_rank_update, _chol_rank_update_grouped
    for d5, k5 in [(24, 3), (29, 5), (64, 9), (130, 2)]:
        x = rng.standard_normal((4 * d5, d5))
        a = x.T @ x + 0.7 * np.eye(d5)
        l = np.linalg.cholesky(a)
        xs = rng.standard_normal((k5, d5))
        out = ops.chol_rank_update(jnp.asarray(l), jnp.asarray(xs))
        ref = _chol_rank_update(l.T, xs).T     # host sweeps the upper R=L.T
        assert rel(out, ref) < TOL, ("rank_update", d5, rel(out, ref))
        # strict upper triangle stays exactly zero through the kernel
        assert np.array_equal(np.triu(np.asarray(out), 1),
                              np.zeros((d5, d5)))
        # k = 0 is the identity
        out0 = ops.chol_rank_update(jnp.asarray(l), jnp.zeros((0, d5)))
        assert np.array_equal(np.asarray(out0), l)
        # stacked micro-batch: one fused call over concatenated roots vs
        # the host grouped sweep over the same sequence
        parts = [xs[:2], np.zeros((0, d5)), xs[2:]]
        outm = ops.chol_rank_update(
            jnp.asarray(l), jnp.asarray(np.concatenate(parts, 0)))
        refm = _chol_rank_update_grouped(l.T, parts).T
        assert rel(outm, refm) < TOL, ("rank_update_many", d5, rel(outm, refm))

    # 6) engine route: factor_update with a LIST of roots folds through
    #    rank_update_many / the fused kernel and matches the host engine
    root_list = [rng.standard_normal((1, d)) for _ in range(3)]
    delta = SuffStats(
        jnp.asarray(sum(np.asarray(r).T @ np.asarray(r) for r in root_list)),
        jnp.zeros_like(sk.moment), jnp.asarray(0.0), jnp.asarray(0.0))
    sk2 = eng_k.merge(sk, delta)
    fk2 = eng_k.factor_update(f, sk2, root=root_list, target_gamma=0.5)
    sh2 = SuffStats(np.asarray(sk2.gram), np.asarray(sk2.moment),
                    float(sk2.count), float(sk2.clients))
    assert rel(eng_k.factor_solve(fk2, sk2.moment),
               eng_h.solve(sh2, target_gamma=0.5)) < TOL
    print("OK")
    """
)


def test_x64_kernel_parity_and_tiled_sharding():
    """1e-10 kernel parity under x64 (interpret-mode Pallas) + the tiled
    8-way device solve ≤1e-6 vs sync — in a subprocess so the process-global
    x64 flag cannot leak into the rest of tier-1."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", _X64_KERNEL_PARITY], capture_output=True,
        text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert res.returncode == 0, res.stderr
    assert "OK" in res.stdout
