#!/usr/bin/env python
"""CI perf-regression gate over the BENCH_solve.json trajectory.

``benchmarks/run.py`` appends one entry per (git sha, suite) with per-bench
wall metrics and the env fingerprint that produced them. This gate compares
the latest entry against the most recent entry of the SAME suite from a
DIFFERENT sha and fails when any shared wall metric regressed by more than
the threshold:

  python tools/bench_gate.py                 # 25% tolerance (tracked perf box)
  python tools/bench_gate.py --smoke         # 200% tolerance (CI runner noise:
                                             #  fail only when >3x slower)
  python tools/bench_gate.py --suite quick:solve_kernels_bench

No prior entry for the suite → pass (first recorded run IS the baseline).
An entry recorded with module failures always fails, regardless of timing.
Metrics present on only one side are reported but never gate — benches come
and go with the code; only like-for-like numbers are comparable. Entries
whose env fingerprints differ are compared with a warning: the numbers are
suspect, but silently passing would hide a real regression.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

DEFAULT_PATH = "results/bench/BENCH_solve.json"
DEFAULT_THRESHOLD = 0.25
SMOKE_THRESHOLD = 2.0
# wall metrics below this are dominated by dispatch jitter, not kernel work
MIN_GATED_SECONDS = 0.05

_ENV_COMPARE = ("JAX_ENABLE_X64", "JAX_DEFAULT_DTYPE_BITS", "XLA_FLAGS",
                "platform", "cpu_count")


def load_trajectory(path: pathlib.Path) -> list:
    try:
        trajectory = json.loads(path.read_text())
    except OSError as exc:
        raise SystemExit(f"bench_gate: cannot read {path}: {exc}")
    except ValueError as exc:
        raise SystemExit(f"bench_gate: {path} is not valid JSON: {exc}")
    if not isinstance(trajectory, list) or not trajectory:
        raise SystemExit(f"bench_gate: {path} holds no recorded runs")
    return trajectory


def pick_entries(trajectory: list, suite: str | None):
    """(current, previous-or-None) for the suite — current is the newest
    matching entry, previous the newest older entry with a different sha."""
    if suite is None:
        suite = trajectory[-1].get("suite")
    matching = [e for e in trajectory if e.get("suite") == suite]
    if not matching:
        raise SystemExit(f"bench_gate: no entries for suite {suite!r}")
    current = matching[-1]
    prev = next((e for e in reversed(matching[:-1])
                 if e.get("sha") != current.get("sha")), None)
    return current, prev


def compare(current: dict, prev: dict, threshold: float):
    """Rows of (key, prev_s, cur_s, ratio, gated_regression) over the wall
    metrics; falls back to per-module seconds when a side has no metrics."""
    cur_m, prev_m = current.get("metrics") or {}, prev.get("metrics") or {}
    if not cur_m or not prev_m:
        cur_m = current.get("modules") or {}
        prev_m = prev.get("modules") or {}
    rows = []
    for key in sorted(set(cur_m) | set(prev_m)):
        c, p = cur_m.get(key), prev_m.get(key)
        if not (isinstance(c, (int, float)) and isinstance(p, (int, float))):
            rows.append((key, p, c, None, False))
            continue
        ratio = c / p if p > 0 else float("inf")
        gated = (ratio > 1.0 + threshold
                 and max(c, p) >= MIN_GATED_SECONDS)
        rows.append((key, p, c, ratio, gated))
    return rows


def _fmt(val) -> str:
    if val is None:
        return "-"
    return f"{val:.3f}" if isinstance(val, float) else str(val)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--path", default=DEFAULT_PATH)
    ap.add_argument("--suite", default=None,
                    help="gate this suite (default: suite of the last entry)")
    ap.add_argument("--threshold", type=float, default=None,
                    help=f"fractional regression tolerance "
                         f"(default {DEFAULT_THRESHOLD})")
    ap.add_argument("--smoke", action="store_true",
                    help=f"loose tolerance ({SMOKE_THRESHOLD:.0%}) for noisy "
                         f"shared CI runners")
    args = ap.parse_args(argv)
    threshold = args.threshold if args.threshold is not None else (
        SMOKE_THRESHOLD if args.smoke else DEFAULT_THRESHOLD)

    trajectory = load_trajectory(pathlib.Path(args.path))
    current, prev = pick_entries(trajectory, args.suite)
    sha, suite = current.get("sha"), current.get("suite")
    print(f"bench_gate: suite={suite!r} current sha={sha} "
          f"recorded={current.get('recorded_at')}")

    if current.get("failures"):
        print(f"bench_gate: FAIL — current entry recorded module failures: "
              f"{current['failures']}")
        return 1
    if prev is None:
        print("bench_gate: PASS — no prior entry for this suite; "
              "this run is the baseline")
        return 0

    print(f"bench_gate: comparing against sha={prev.get('sha')} "
          f"recorded={prev.get('recorded_at')} "
          f"(tolerance {threshold:.0%})")
    env_c, env_p = current.get("env") or {}, prev.get("env") or {}
    drift = [k for k in _ENV_COMPARE if env_c.get(k) != env_p.get(k)]
    if drift:
        print(f"bench_gate: WARNING — env fingerprint drift on {drift}; "
              f"numbers may not be comparable")

    rows = compare(current, prev, threshold)
    regressions = [r for r in rows if r[4]]
    width = max([len(r[0]) for r in rows] + [6])
    print(f"  {'metric':<{width}}  {'prev_s':>9}  {'cur_s':>9}  "
          f"{'ratio':>6}  flag")
    for key, p, c, ratio, gated in rows:
        flag = ("REGRESSED" if gated else
                "new" if p is None else
                "gone" if c is None else "")
        print(f"  {key:<{width}}  {_fmt(p):>9}  {_fmt(c):>9}  "
              f"{_fmt(ratio):>6}  {flag}")

    if regressions:
        print(f"bench_gate: FAIL — {len(regressions)} metric(s) regressed "
              f"more than {threshold:.0%}")
        return 1
    print("bench_gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
