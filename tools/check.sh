#!/usr/bin/env bash
# CI-style smoke check: tier-1 test suite + one reduced end-to-end analytic
# training run through the engine (backbone forward → streaming Gram stats →
# solve). Run from anywhere; ~2-4 min on CPU.
#
#   tools/check.sh            # full tier-1 pytest + reduced train run
#   tools/check.sh --fast     # -x (stop at first failure) variant
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

PYTEST_ARGS=(-q)
if [[ "${1:-}" == "--fast" ]]; then
  PYTEST_ARGS=(-x -q)
fi

echo "== tier-1: pytest ${PYTEST_ARGS[*]}"
python -m pytest "${PYTEST_ARGS[@]}"

echo "== smoke: reduced analytic training run (launch/train.py)"
python -m repro.launch.train --arch minicpm_2b --mode analytic --reduced \
    --samples 512 --seq 16 --classes 8 --batch 64

echo "== check.sh OK"
