#!/usr/bin/env bash
# CI-style smoke check: tier-1 test suite + one reduced end-to-end analytic
# training run through the engine (backbone forward → streaming Gram stats →
# solve) + the quick solve-kernel bench behind the perf-regression gate.
# Run from anywhere; ~3-5 min on CPU.
#
#   tools/check.sh            # full tier-1 pytest + reduced train run + bench
#   tools/check.sh --fast     # -x (stop at first failure) variant, no bench
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Environment truth (SNIPPETS.md): tcmalloc when present, and silence its
# large-alloc reports. Harmless for pytest, required for comparable bench
# numbers (benchmarks/env_truth.py records the effective set per entry).
TCMALLOC_SO=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [[ -z "${LD_PRELOAD:-}" && -e "$TCMALLOC_SO" ]]; then
  export LD_PRELOAD="$TCMALLOC_SO"
fi
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000

PYTEST_ARGS=(-q)
RUN_BENCH=1
if [[ "${1:-}" == "--fast" ]]; then
  PYTEST_ARGS=(-x -q)
  RUN_BENCH=0
fi

# Tier-1 runs under the default dtype config on purpose: the x64 double
# config below is bench truth, but globally forcing JAX_ENABLE_X64 changes
# index/scalar dtypes that several tier-1 suites pin to 32-bit.
echo "== tier-1: pytest ${PYTEST_ARGS[*]}"
python -m pytest "${PYTEST_ARGS[@]}"

echo "== smoke: reduced analytic training run (launch/train.py)"
python -m repro.launch.train --arch minicpm_2b --mode analytic --reduced \
    --samples 512 --seq 16 --classes 8 --batch 64

echo "== smoke: elastic failover drill (grow → crash → resharded restore)"
python examples/failover_drill.py

echo "== smoke: replication drill (kill primary mid-stream → standby + replica)"
python examples/replication_drill.py

if [[ "$RUN_BENCH" == "1" ]]; then
  # The double config (f64 allowed, f32 default) scoped to the bench step:
  # recorded numbers must match the env fingerprint in BENCH_solve.json.
  echo "== bench: quick solve-kernel suite + perf-regression gate"
  JAX_ENABLE_X64=1 JAX_DEFAULT_DTYPE_BITS=32 \
    python -m benchmarks.run --quick --only solve_kernels_bench
  python tools/bench_gate.py --smoke --suite quick:solve_kernels_bench

  # Separate suite key: the replica-read trajectory gates against its own
  # history, never against the solve-kernel baseline.
  echo "== bench: quick replica-read suite (recorded trajectory)"
  python -m benchmarks.run --quick --only replica_read_bench

  # Serving-transport latencies gate against their own quick:load_harness
  # history (http vs mux, TLS on/off, auth always on).
  echo "== bench: load-harness smoke (http vs mux) + perf-regression gate"
  python -m benchmarks.run --quick --only load_harness
  python tools/bench_gate.py --smoke --suite quick:load_harness
fi

echo "== check.sh OK"
