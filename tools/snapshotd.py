#!/usr/bin/env python
"""Standalone snapshot daemon for a live AFL federation.

Runs OUTSIDE the serving process (its whole point: it must survive a
coordinator crash), periodically pulling checkpoint-over-wire ``state`` from
a :class:`~repro.fl.service.FederationService` and writing versioned
checkpoint directories a replacement coordinator can cold-start from — any
kind, any shard count:

  PYTHONPATH=src python tools/snapshotd.py --url http://127.0.0.1:8790 \
      --dir /var/afl/snapshots --interval 30 --keep 5

  # failover: bring up a replacement from the latest snapshot
  PYTHONPATH=src python -m repro.launch.serve --federation \
      --coordinator sharded --shards 8 \
      --restore-from /var/afl/snapshots/snap-000000000042

``--once`` takes a single snapshot and exits (cron-style operation). A pull
that fails (service down — exactly when the existing snapshots matter) is
logged and retried on the next tick, never fatal.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.checkpoint import SnapshotDaemon  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--url", required=True,
                    help="federation service URL, e.g. http://127.0.0.1:8790")
    ap.add_argument("--dir", required=True,
                    help="snapshot directory (created if missing)")
    ap.add_argument("--interval", type=float, default=30.0,
                    help="seconds between pulls")
    ap.add_argument("--keep", type=int, default=5,
                    help="snapshots retained (older ones pruned)")
    ap.add_argument("--federation", default="default",
                    help="federation id to snapshot")
    ap.add_argument("--once", action="store_true",
                    help="take one snapshot and exit")
    ap.add_argument("--ledger-dir", default=None,
                    help="the primary's submit-ledger directory: each "
                         "successful tick compacts it to what the snapshot "
                         "covers (out-of-process safe — only sealed "
                         "segments are dropped, never the active one)")
    ap.add_argument("--auth-token", default=None,
                    help="bearer token for an auth-gated federation")
    args = ap.parse_args()

    daemon = SnapshotDaemon(args.url, directory=args.dir,
                            interval=args.interval, keep=args.keep,
                            federation=args.federation,
                            ledger=args.ledger_dir,
                            auth_token=args.auth_token)
    if args.once:
        path = daemon.snapshot_once()
        print(f"snapshot: {path if path else 'already current'}")
        return 0
    print(f"snapshotd: {args.url} → {args.dir} every {args.interval:g}s "
          f"(keep {args.keep}); ctrl-c to stop")
    daemon.start()
    try:
        import threading

        threading.Event().wait()
    except KeyboardInterrupt:
        pass
    finally:
        daemon.stop()
    if daemon.errors:
        print(f"{len(daemon.errors)} failed pulls; last: "
              f"{daemon.errors[-1][1]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
