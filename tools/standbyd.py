#!/usr/bin/env python
"""Warm-standby daemon: tail the submit ledger, promote on primary death.

Runs OUTSIDE the primary's process (like ``snapshotd.py``, its whole point
is surviving the primary). Cold-starts a coordinator from the newest
snapshot under ``--snapshot-dir`` (or from scratch via ``--dim/--classes``),
then tails the primary's :class:`~repro.fl.replication.ReportLedger` under
``--ledger-dir`` so every acked submit — including ones the snapshot never
saw — is already folded the moment promotion is needed. While the primary
answers liveness probes (``--watch-url``) the standby serves retryable 503s;
after ``--grace`` consecutive failures it promotes and starts serving writes
itself, appending to the SAME ledger so the failover chain can repeat:

  PYTHONPATH=src python tools/standbyd.py \
      --ledger-dir /var/afl/ledger --snapshot-dir /var/afl/snapshots \
      --watch-url http://127.0.0.1:8790 --grace 3 --port 8791

``--once`` replays ledger + snapshot, prints the recovered position, and
exits without serving (an offline restore check). Promotion is bit-for-bit:
the AA law makes the ledger an order-insensitive sum, so snapshot prefix +
ledger suffix equals the never-crashed aggregate exactly (f64).
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import threading

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.fl import (AFLServer, AsyncAFLServer,  # noqa: E402
                      ShardedCoordinator, WarmStandby, watch_primary)
from repro.fl.mux import probe_alive  # noqa: E402
from repro.fl.service import FederationService, serve_http  # noqa: E402

_KINDS = {"sync": AFLServer, "async": AsyncAFLServer,
          "sharded": ShardedCoordinator}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ledger-dir", required=True,
                    help="the primary's submit ledger directory")
    ap.add_argument("--snapshot-dir", default=None,
                    help="snapshotd directory to cold-start from")
    ap.add_argument("--watch-url", default=None,
                    help="primary URL to probe (http(s):// describes, "
                         "mux(s):// rides a PING frame); omit with --once")
    ap.add_argument("--watch-cafile", default=None,
                    help="CA PEM for probing a TLS primary (muxs/https)")
    ap.add_argument("--watch-token", default=None,
                    help="bearer token for http(s) probes of an "
                         "auth-gated primary (mux PING needs none)")
    ap.add_argument("--grace", type=int, default=3,
                    help="consecutive failed probes before promotion")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="seconds between liveness probes")
    ap.add_argument("--coordinator", default="sync", choices=sorted(_KINDS),
                    help="coordinator kind to restore as (any kind can "
                         "replay any ledger)")
    ap.add_argument("--dim", type=int, default=None,
                    help="bootstrap dim when no snapshot exists yet")
    ap.add_argument("--classes", type=int, default=None)
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8791)
    ap.add_argument("--once", action="store_true",
                    help="replay snapshot + ledger, report, exit")
    args = ap.parse_args()

    ctor_kw = None
    if args.dim is not None and args.classes is not None:
        ctor_kw = dict(dim=args.dim, num_classes=args.classes,
                       gamma=args.gamma)
    standby = WarmStandby(args.ledger_dir, snapshot_dir=args.snapshot_dir,
                          cls=_KINDS[args.coordinator], ctor_kw=ctor_kw)

    if args.once:
        folded = standby.catch_up()
        c = standby.coordinator
        print(f"replayed to seq {standby.position} "
              f"(+{folded} applied, {standby.skipped} already in snapshot): "
              f"{type(c).__name__} with {c.num_clients} clients "
              f"at version {c.version}")
        return 0
    if not args.watch_url:
        ap.error("--watch-url is required unless --once")

    service = FederationService()
    service.host_standby("default", standby)
    with service, serve_http(service, args.host, args.port) as srv:
        print(f"standbyd: tailing {args.ledger_dir}, watching "
              f"{args.watch_url} (grace {args.grace}); standby at {srv.url} "
              "answers 503 until promoted; ctrl-c to stop")

        def _alive() -> bool:
            return probe_alive(args.watch_url, cafile=args.watch_cafile,
                               auth_token=args.watch_token)

        stop = threading.Event()
        try:
            coordinator = watch_primary(
                standby, _alive, grace=args.grace, interval=args.interval,
                stop=stop,
                on_promote=lambda c: service.promote_federation())
        except KeyboardInterrupt:
            stop.set()
            return 0
        if coordinator is not None:
            print(f"PROMOTED: {type(coordinator).__name__} with "
                  f"{coordinator.num_clients} clients now serving writes "
                  f"at {srv.url} (zero reports lost)")
            try:
                threading.Event().wait()
            except KeyboardInterrupt:
                print("shutting down")
    return 0


if __name__ == "__main__":
    sys.exit(main())
